"""Open-loop arrival generation for the fleet simulator (DESIGN.md L2).

Closed-loop workloads - a fixed population of streams whose next request
waits for the previous one - self-throttle as latency grows, which *hides*
scalability collapse: the offered load falls exactly when the system is
drowning.  The USL-style collapse sweep needs offered load to be an
independent variable, so the cluster subsystem drives replicas with
**open-loop** arrival processes (arrivals do not care how the fleet is
doing):

* ``poisson``  - homogeneous Poisson at a target RPS;
* ``bursty``   - two-state Markov-modulated Poisson (calm/burst), mean
  rate held at the target RPS - the flash-crowd shape that defeats
  averaged-occupancy routing;
* ``diurnal``  - sinusoidal ramp-up/ramp-down over the window (thinned
  Poisson), the daily traffic curve an autoscaler must track;
* ``sessions`` - multi-turn conversations: session starts are Poisson,
  each session runs several turns separated by exponential think time,
  and every follow-up turn's prompt carries the full conversation
  history as a KV-shareable prefix (``Request.session_id`` /
  ``prefix_id`` / ``prefix_len``) - the workload where routing a turn
  away from its warm replica costs real prefill;
* ``replay``   - seeded trace replay from explicit rows (``to_trace``
  round-trips any generated workload, sessions included);
* ``uniform``  - the legacy serving-bench shape (uniform arrivals in a
  window), kept for the single-replica benches.

All generators are exactly deterministic under a fixed seed.  Sessions
stay **open-loop**: every turn's arrival time is drawn up front, so a
drowning fleet still receives the follow-up turns on schedule (a real
user re-prompts whether or not the previous answer was fast).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..serving.engine import Request

WORKLOADS = ("poisson", "bursty", "diurnal", "sessions", "uniform")


@dataclass(frozen=True)
class WorkloadSpec:
    """Per-request shape distribution (lengths in tokens)."""

    prompt_range: Tuple[int, int] = (256, 1024)
    gen_range: Tuple[int, int] = (64, 256)
    n_pods: int = 2

    @property
    def mean_prompt(self) -> float:
        return 0.5 * (self.prompt_range[0] + self.prompt_range[1])

    @property
    def mean_gen(self) -> float:
        return 0.5 * (self.gen_range[0] + self.gen_range[1])

    @property
    def mean_resident(self) -> float:
        """Mean KV-resident tokens of an in-flight request (full prompt +
        half the generation) - the footprint capacity math keys off."""
        return self.mean_prompt + self.mean_gen / 2


DEFAULT_SPEC = WorkloadSpec()


def _materialize(arrive_ms: Sequence[float], spec: WorkloadSpec,
                 rng: np.random.Generator, start_rid: int = 0
                 ) -> List[Request]:
    """Attach prompt/gen lengths and a pod to each arrival time.

    Pods are *drawn*, not assigned round-robin: a deterministic
    ``rid % n_pods`` pattern happens to agree with round-robin routing
    (request k -> replica k % n), which would hand the occupancy-blind
    baseline accidental pod purity."""
    out = []
    for i, t in enumerate(arrive_ms):
        rid = start_rid + i
        out.append(Request(
            rid=rid,
            prompt_len=int(rng.integers(*spec.prompt_range)),
            gen_len=int(rng.integers(*spec.gen_range)),
            pod=int(rng.integers(0, spec.n_pods)),
            arrive_ms=float(t)))
    return out


def poisson(rps: float, duration_ms: float, spec: WorkloadSpec = DEFAULT_SPEC,
            seed: int = 0, start_rid: int = 0) -> List[Request]:
    """Homogeneous Poisson arrivals at ``rps`` over ``duration_ms``."""
    if rps <= 0:
        return []
    rng = np.random.default_rng(seed)
    rate_per_ms = rps / 1e3
    times: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_per_ms)
        if t >= duration_ms:
            break
        times.append(t)
    return _materialize(times, spec, rng, start_rid)


def bursty(rps: float, duration_ms: float, spec: WorkloadSpec = DEFAULT_SPEC,
           seed: int = 0, burst_factor: float = 4.0,
           dwell_ms: Tuple[float, float] = (2000.0, 500.0),
           start_rid: int = 0) -> List[Request]:
    """Two-state Markov-modulated Poisson process (calm <-> burst).

    State dwell times are exponential with means ``dwell_ms``; the burst
    state arrives ``burst_factor`` x faster than the calm state, with the
    calm rate solved so the *time-averaged* rate equals ``rps`` - sweeps
    stay comparable with ``poisson`` at the same nominal load.
    """
    if rps <= 0:
        return []
    rng = np.random.default_rng(seed)
    d0, d1 = dwell_ms
    # stationary occupancy of each state is proportional to its mean dwell
    pi1 = d1 / (d0 + d1)
    calm = rps / (1.0 - pi1 + pi1 * burst_factor)
    rates_per_ms = (calm / 1e3, calm * burst_factor / 1e3)
    times: List[float] = []
    t, state = 0.0, 0
    state_end = rng.exponential(d0)
    while t < duration_ms:
        gap = rng.exponential(1.0 / rates_per_ms[state])
        if t + gap >= state_end:
            # advance to the state boundary, switch, and redraw there
            t = state_end
            state = 1 - state
            state_end = t + rng.exponential(dwell_ms[state])
            continue
        t += gap
        if t < duration_ms:
            times.append(t)
    return _materialize(times, spec, rng, start_rid)


def diurnal(rps_peak: float, duration_ms: float,
            spec: WorkloadSpec = DEFAULT_SPEC, seed: int = 0,
            floor: float = 0.1, start_rid: int = 0,
            cycles: int = 1, phase: float = 0.0) -> List[Request]:
    """Sinusoidal ramp:
    rate(t) = peak * (floor + (1-floor) sin^2(pi (cycles t/T + phase))).

    Implemented by thinning a homogeneous Poisson at the peak rate, so the
    arrival stream is exact, not binned.  ``cycles`` repeats the daily
    curve (a multi-day trace for seasonality-aware controllers);
    ``phase`` shifts it in units of a full cycle, so two streams at
    phases 0 and 0.25 peak a quarter-day apart.  The defaults
    ``cycles=1, phase=0.0`` evaluate the exact historical expression
    (``1*t/T + 0.0 == t/T`` in floats), so existing seeded traces are
    bit-identical.
    """
    if rps_peak <= 0:
        return []
    rng = np.random.default_rng(seed)
    rate_per_ms = rps_peak / 1e3
    times: List[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / rate_per_ms)
        if t >= duration_ms:
            break
        frac = floor + (1.0 - floor) \
            * np.sin(np.pi * (cycles * t / duration_ms + phase)) ** 2
        if rng.uniform() < frac:
            times.append(t)
    return _materialize(times, spec, rng, start_rid)


def pod_skewed_diurnal(rps_peak: float, duration_ms: float,
                       spec: WorkloadSpec = DEFAULT_SPEC, seed: int = 0,
                       floor: float = 0.1, cycles: int = 1,
                       phases: Sequence[float] = (0.0, 0.25),
                       amp_scale: Optional[Sequence[float]] = None,
                       floors: Optional[Sequence[float]] = None
                       ) -> List[Request]:
    """Per-pod skewed diurnal load: pod ``p`` receives its own diurnal
    stream at ``phases[p]`` of a cycle with peak
    ``rps_peak * amp_scale[p]`` and floor ``floors[p]``, so the pods
    saturate at *different times and depths* - the workload where a
    pool-scalar controller wastes spawns on whichever pod index parity
    points at, while a pod-scoped controller grows the pod that is
    actually burning.  ``floors[p] = 1.0`` makes pod ``p`` a flat
    (phase-free) stream - the steady-traffic pod beside a swinging one
    is the hardest skew for aggregate signals, which see only the blend.
    Each pod's stream draws from an independent seeded generator
    (``seed + p``); requests are force-stamped with their pod and merged
    by arrival time with globally unique rids.
    """
    amp_scale = amp_scale if amp_scale is not None else [1.0] * len(phases)
    floors = floors if floors is not None else [floor] * len(phases)
    streams: List[List[Request]] = []
    offset = 0
    for p, phase in enumerate(phases):
        s = diurnal(rps_peak * amp_scale[p], duration_ms, spec,
                    seed=seed + p, floor=floors[p], start_rid=offset,
                    cycles=cycles, phase=phase)
        for r in s:
            r.pod = p          # the stream IS this pod's traffic
        offset += len(s)
        streams.append(s)
    merged = [r for s in streams for r in s]
    merged.sort(key=lambda r: (r.arrive_ms, r.rid))
    return merged


def sessions(rps: float, duration_ms: float, spec: WorkloadSpec = DEFAULT_SPEC,
             seed: int = 0, turns_range: Tuple[int, int] = (2, 6),
             think_ms: float = 1500.0,
             followup_range: Tuple[int, int] = (16, 96),
             start_rid: int = 0,
             prefix_groups: int = 0,
             group_zipf: float = 1.2,
             sys_prompt_range: Tuple[int, int] = (128, 512)
             ) -> List[Request]:
    """Multi-turn conversation arrivals at a target *request* rate ``rps``.

    Session starts are homogeneous Poisson at ``rps / mean(turns_range)``
    so the time-averaged turn rate matches ``rps`` (sweeps stay comparable
    with ``poisson`` at the same nominal load; turns cut off at the window
    edge shave the realized rate slightly).  Each session draws a turn
    count, a pod (conversations do not hop pods), and an opening prompt;
    follow-up turns arrive an exponential think time after the previous
    turn and their prompt is the full history so far (``prefix_len``
    KV-shareable tokens) plus a fresh user message from
    ``followup_range``.  ``prefix_id == session_id``: one conversation is
    one prefix group.

    **Shared system-prompt prefix groups** (``prefix_groups > 0``): every
    session additionally belongs to one of ``prefix_groups`` groups -
    think product surfaces sharing a system prompt - drawn Zipf-ish
    (group ``k`` with weight ``(k+1)^-group_zipf``, so group 0 is hot and
    the tail is cold: realistic cache skew).  The group's system prompt
    (length from ``sys_prompt_range``, drawn once per group) prefixes the
    opening prompt, so even a session's *first* turn has
    ``prefix_len > 0`` and can land warm where its group is cached;
    ``prefix_id`` is the *group* id for every turn (many sessions, one
    prefix group - the group's cache entry pools the longest history
    materialized on that replica).  ``to_trace``/``replay`` round-trip
    both forms (session, group, and prefix length all ride the 7-column
    rows).  ``prefix_groups=0`` (default) draws nothing extra and is
    bit-identical to the historical generator.
    """
    if rps <= 0:
        return []
    rng = np.random.default_rng(seed)
    grouped = prefix_groups > 0
    if grouped:
        # group state up front, so per-session draw order is stable
        sys_len = [int(rng.integers(*sys_prompt_range))
                   for _ in range(prefix_groups)]
        w = np.arange(1, prefix_groups + 1, dtype=np.float64) ** -group_zipf
        w /= w.sum()
    mean_turns = 0.5 * (turns_range[0] + turns_range[1])
    start_rate_per_ms = rps / mean_turns / 1e3
    rows = []    # (arrive_ms, session, prompt, gen, prefix_id, pfx_len, pod)
    t, sid = 0.0, 0
    while True:
        t += rng.exponential(1.0 / start_rate_per_ms)
        if t >= duration_ms:
            break
        n_turns = int(rng.integers(turns_range[0], turns_range[1] + 1))
        pod = int(rng.integers(0, spec.n_pods))
        if grouped:
            group = int(rng.choice(prefix_groups, p=w))
            base = sys_len[group]
        else:
            group, base = sid, 0
        at, history = t, base
        for _turn in range(n_turns):
            if at >= duration_ms:
                break
            new_toks = (int(rng.integers(*spec.prompt_range))
                        if history == base
                        else int(rng.integers(*followup_range)))
            gen = int(rng.integers(*spec.gen_range))
            # the opening turn's shareable prefix is the group's system
            # prompt (0 in ungrouped mode); follow-ups share their full
            # history, system prompt included
            rows.append((at, sid, history + new_toks, gen, group, history,
                         pod))
            history += new_toks + gen
            at += rng.exponential(think_ms)
        sid += 1
    rows.sort(key=lambda e: (e[0], e[1]))
    return [Request(rid=start_rid + i, prompt_len=p, gen_len=g, pod=pod,
                    arrive_ms=a, session_id=s, prefix_id=pid,
                    prefix_len=pfx)
            for i, (a, s, p, g, pid, pfx, pod) in enumerate(rows)]


def to_trace(requests: Sequence[Request]) -> List[Tuple]:
    """Serialize any workload to replayable rows (``replay`` round-trips
    this, session identity included)."""
    return [(r.arrive_ms, r.prompt_len, r.gen_len, r.pod,
             r.session_id, r.prefix_id, r.prefix_len) for r in requests]


def replay(trace: Iterable[Tuple], start_rid: int = 0) -> List[Request]:
    """Replay explicit trace rows ``(arrive_ms, prompt_len, gen_len, pod)``
    or the 7-column ``to_trace`` form with
    ``(..., session_id, prefix_id, prefix_len)`` appended."""
    out = []
    for i, row in enumerate(trace):
        if len(row) not in (4, 7):
            # a 5/6-column row would silently lose its session identity
            raise ValueError(f"trace row {i} has {len(row)} columns; "
                             "expected 4 (legacy) or 7 (to_trace)")
        t, p, g, pod = row[:4]
        s, pfx_id, pfx_len = row[4:] if len(row) == 7 else (-1, -1, 0)
        out.append(Request(rid=start_rid + i, prompt_len=int(p),
                           gen_len=int(g), pod=int(pod), arrive_ms=float(t),
                           session_id=int(s), prefix_id=int(pfx_id),
                           prefix_len=int(pfx_len)))
    out.sort(key=lambda r: (r.arrive_ms, r.rid))
    return out


def uniform(n: int, window_ms: float = 500.0,
            spec: WorkloadSpec = DEFAULT_SPEC, seed: int = 0,
            start_rid: int = 0) -> List[Request]:
    """Legacy single-replica bench shape: n requests, arrivals uniform in
    ``[0, window_ms)``.  Draw order matches the historical serving-bench
    generator so seeded results stay bit-identical."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        rid = start_rid + i
        out.append(Request(
            rid=rid,
            prompt_len=int(rng.integers(*spec.prompt_range)),
            gen_len=int(rng.integers(*spec.gen_range)),
            pod=rid % spec.n_pods,
            arrive_ms=float(rng.uniform(0, window_ms))))
    return out


def make_workload(kind: str, rps: float, duration_ms: float,
                  spec: WorkloadSpec = DEFAULT_SPEC, seed: int = 0
                  ) -> List[Request]:
    """Dispatcher used by benches and the launcher.  For ``uniform`` the
    request count is derived from rps * duration."""
    if kind == "poisson":
        return poisson(rps, duration_ms, spec, seed)
    if kind == "bursty":
        return bursty(rps, duration_ms, spec, seed)
    if kind == "diurnal":
        return diurnal(rps, duration_ms, spec, seed)
    if kind == "sessions":
        return sessions(rps, duration_ms, spec, seed)
    if kind == "uniform":
        return uniform(int(rps * duration_ms / 1e3), duration_ms, spec, seed)
    raise ValueError(f"unknown workload kind {kind!r}")
