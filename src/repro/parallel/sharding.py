"""Sharding rules: map params / batches / caches / optimizer state to the
production mesh (DP x TP (+EP/SP), hierarchical DP across pods).

Scheme (DESIGN.md section 5):

* **DP**: batch over ``data`` (and ``pod`` when multi-pod).
* **TP** over ``model``: attention by flat Q heads (KV is repeated up to the
  query head count in train/prefill - the MaxText "kv replication" trick -
  so one mesh axis shards one dim); MLP column->row; vocab on the model axis
  for both embedding and LM head.
* **EP** over ``model`` for MoE expert banks when n_experts divides the axis
  (granite 32e); otherwise TP inside experts (mixtral 8e on 16 shards).
* **SP**: the train/prefill residual stream is sharded (dp, model, None) on
  (B, S, D) - Megatron-style sequence parallelism; XLA inserts the
  all-gather/reduce-scatter pairs around attention/MLP.
* **Decode**: batch on ``data`` when divisible; KV caches sharded along the
  *sequence* dim on ``model`` (and on ``data`` too for batch=1 long-context)
  - a GSPMD-native distributed flash-decode; SSM/WKV states shard heads on
  ``model``.
* **ZeRO-1**: optimizer moments additionally shard their largest replicated
  dim over the DP axes.

Divisibility is always checked; a dim that does not divide its axis stays
replicated (e.g. whisper's 8 heads on a 16-way model axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..config import MeshConfig, ModelConfig, ShapeSpec


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


class ShardingRules:
    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 shape: Optional[ShapeSpec] = None,
                 fsdp: bool = True) -> None:
        self.cfg = cfg
        self.mesh = mesh
        self.shape = shape
        self.multi_pod = "pod" in mesh.axis_names
        self.dp: Tuple[str, ...] = (("pod", "data") if self.multi_pod
                                    else ("data",))
        self.tp = "model"
        self.dp_size = _axis_size(mesh, self.dp)
        self.tp_size = _axis_size(mesh, self.tp)
        # FSDP: additionally shard large weights over the data axes (their
        # stacked-layer dim when divisible); XLA gathers each layer's slice
        # on demand inside the scan (fully-sharded data parallelism).
        self.fsdp = fsdp
        self.fsdp_min_elems = 1 << 20
        # dp-only policy (perf: EXPERIMENTS.md section Perf, H1): when the
        # per-shard model width would fall under one MXU lane tile (128),
        # tensor parallelism produces sub-tile shards and resharding storms;
        # for TRAIN shapes with batch divisible by the whole mesh, fold the
        # model axis into data parallelism instead (params FSDP-sharded).
        if (shape is not None and shape.kind == "train"
                and cfg.d_model // max(self.tp_size, 1) < 128
                and shape.global_batch % (self.dp_size * self.tp_size) == 0):
            self.dp = tuple(self.dp) + (self.tp,)
            self.dp_size *= self.tp_size
            self.tp = None
            self.tp_size = 1

    # -- helpers -------------------------------------------------------------
    def _maybe(self, dim: int, axis) -> Optional[Any]:
        """axis if dim divides its total size, else None (replicated)."""
        return axis if dim % _axis_size(self.mesh, axis) == 0 else None

    def _batch_axis(self, b: int):
        return self.dp if b % self.dp_size == 0 else None

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    # -- parameters -----------------------------------------------------------
    def _param_spec(self, path: Tuple[str, ...], shape: Tuple[int, ...]) -> P:
        name = path[-1]
        parent = path[-2] if len(path) >= 2 else ""
        tp = self.tp
        nd = len(shape)

        def spec_from(last_dims: Dict[int, Any]) -> P:
            entries = [None] * nd
            for rel, axis in last_dims.items():
                if axis is not None and shape[nd + rel] % _axis_size(
                        self.mesh, axis) == 0:
                    entries[nd + rel] = axis
            return P(*entries)

        if name == "embed":
            return spec_from({-2: tp})            # vocab-sharded
        if name == "lm_head":
            return spec_from({-1: tp})
        if name == "frontend_proj":
            return spec_from({-1: tp})
        if parent in ("attn", "cross"):
            # Output-dim (column) sharding only when the head count divides
            # the axis, so the flat->(heads, d_head) reshape stays
            # GSPMD-expressible; otherwise shard the input (row) dim - the
            # projection output is then replicated and reshaped locally
            # (avoids involuntary full rematerializations in SPMD).
            heads_ok = self.cfg.n_heads % self.tp_size == 0
            kv_ok = self.cfg.n_kv_heads % self.tp_size == 0
            if name == "wq":
                return spec_from({-1: tp} if heads_ok else {})
            if name in ("wk", "wv"):
                # replicated when kv heads don't divide the axis: the
                # projections are small and the activations then keep their
                # batch/seq sharding (no full-batch regather)
                return spec_from({-1: tp} if kv_ok else {})
            if name == "wo":
                return spec_from({-2: tp} if heads_ok else {})
            return P(*([None] * nd))              # q_norm / k_norm
        if parent == "mlp":
            if name in ("wi_gate", "wi_up"):
                return spec_from({-1: tp})
            if name == "wo":
                return spec_from({-2: tp})
        if parent == "moe":
            if name == "router":
                return P(*([None] * nd))
            ep = self.cfg.n_experts % self.tp_size == 0
            if ep:
                return spec_from({-3: tp})        # expert-parallel bank
            if name in ("wi_gate", "wi_up"):
                return spec_from({-1: tp})
            return spec_from({-2: tp})
        if parent == "mamba":
            if name in ("w_z", "w_x"):
                return spec_from({-1: tp})
            if name in ("conv_x_w", "conv_x_b", "norm_w"):
                return spec_from({-1: tp})
            if name == "out_proj":
                return spec_from({-2: tp})
            return P(*([None] * nd))
        if parent == "rwkv":
            if name in ("w_r", "w_k", "w_v", "w_g", "c_k"):
                return spec_from({-1: tp})
            if name in ("w_o", "c_v"):
                return spec_from({-2: tp})
            return P(*([None] * nd))
        return P(*([None] * nd))                  # norms, scalars, misc

    def _apply_fsdp(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Shard the LEADING (stacked-layer / vocab) dim over dp.

        Never falls through to inner dims: sharding a matmul's contraction
        dim over dp forces XLA to reshard the activations off their batch
        sharding (full-batch regathers inside the layer loop - measured as
        a 10x collective-term regression on deepseek/zamba2 before this
        guard; see EXPERIMENTS.md section Perf)."""
        size = 1
        for d in shape:
            size *= d
        if size < self.fsdp_min_elems or not shape:
            return spec
        entries = list(spec) + [None] * (len(shape) - len(spec))
        dp_axis = self.dp if self.multi_pod else self.dp[0]
        if entries[0] is None and shape[0] % self.dp_size == 0 \
                and shape[0] > 1:
            entries[0] = dp_axis
            return P(*entries)
        return spec

    def param_specs(self, params_tree) -> Any:
        def f(path, leaf):
            keys = tuple(getattr(p, "key", getattr(p, "idx", None))
                         for p in path)
            spec = self._param_spec(keys, leaf.shape)
            if self.fsdp:
                spec = self._apply_fsdp(spec, leaf.shape)
            return spec
        return jax.tree_util.tree_map_with_path(f, params_tree)

    def param_shardings(self, params_tree):
        return jax.tree.map(self.sharding, self.param_specs(params_tree))

    # -- optimizer state (ZeRO-1) ----------------------------------------------
    def zero1_spec(self, spec: P, shape: Tuple[int, ...]) -> P:
        entries = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for e in entries:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    used.add(a)
        if any(a in used for a in self.dp):
            return P(*entries)   # FSDP already shards over dp
        for i, (e, d) in enumerate(zip(entries, shape)):
            if e is None and d % self.dp_size == 0 and d > 1:
                entries[i] = self.dp if self.multi_pod else self.dp[0]
                break
        return P(*entries)

    def opt_specs(self, params_tree, zero1: bool = True):
        pspecs = self.param_specs(params_tree)

        def f(spec, leaf):
            return self.zero1_spec(spec, leaf.shape) if zero1 else spec
        return jax.tree.map(f, pspecs, params_tree)

    # -- batches ----------------------------------------------------------------
    def batch_specs(self, batch_tree) -> Any:
        def f(leaf):
            b = leaf.shape[0]
            entries = [self._batch_axis(b)] + [None] * (len(leaf.shape) - 1)
            return P(*entries)
        return jax.tree.map(f, batch_tree)

    # -- caches -----------------------------------------------------------------
    def cache_specs(self, cache_tree, batch: int) -> Any:
        """Decode-cache sharding.  Leaves are (L, B, ...) stacked buffers."""
        b_axis = self._batch_axis(batch)

        def f(path, leaf):
            keys = [str(getattr(p, "key", "")) for p in path]
            nd = len(leaf.shape)
            if nd == 0:                       # pos scalar
                return P()
            name = keys[-1]
            entries: list = [None] * nd
            if name in ("k", "v") and nd == 5:
                # (L, B, T, kv, dh): batch on dp; seq on model (+dp if b=1)
                entries[1] = b_axis
                seq_axes = (self.tp if b_axis is not None
                            else (tuple(self.dp) + (self.tp,)))
                entries[2] = self._maybe(leaf.shape[2], seq_axes)
            elif name == "ssm" and nd == 5:    # (L,B,H,P,N)
                entries[1] = b_axis
                entries[2] = self._maybe(leaf.shape[2], self.tp)
            elif name == "wkv" and nd == 5:    # (L,B,H,P,P)
                entries[1] = b_axis
                entries[2] = self._maybe(leaf.shape[2], self.tp)
            elif nd >= 2:                      # shifts, conv states, misc
                entries[1] = b_axis
                if name == "x" and nd == 4:    # mamba conv state (L,B,K,di)
                    entries[3] = self._maybe(leaf.shape[3], self.tp)
            return P(*entries)
        return jax.tree_util.tree_map_with_path(f, cache_tree)

    def cache_shardings(self, cache_tree, batch: int):
        return jax.tree.map(self.sharding,
                            self.cache_specs(cache_tree, batch))

    # -- activation constraints ---------------------------------------------------
    def constrain(self, x, kind: str = "residual"):
        """Pin intermediate activations to the mesh (called by the model)."""
        mesh = self.mesh
        if kind == "residual":
            if x.ndim != 3:
                return x
            b, s, _ = x.shape
            b_axis = self._batch_axis(b)
            s_axis = self._maybe(s, self.tp) if s > 1 else None
            spec = P(b_axis, s_axis, None)
        elif kind == "logits":
            b = x.shape[0]
            spec = P(self._batch_axis(b), None,
                     self._maybe(x.shape[-1], self.tp))
        elif kind == "heads":
            # q/k/v in flat-head layout (B, S, H, D): heads on model
            if x.ndim != 4:
                return x
            spec = P(self._batch_axis(x.shape[0]), None,
                     self._maybe(x.shape[2], self.tp), None)
        elif kind == "moe_buf":
            # (B, E, C, D) grouped expert capacity buffer: groups on dp,
            # experts on model (EP) when E divides the axis, else TP on D
            if x.ndim != 4:
                return x
            b_axis = self._batch_axis(x.shape[0])
            if x.shape[1] % self.tp_size == 0:
                spec = P(b_axis, self.tp, None, None)
            else:
                spec = P(b_axis, None, None,
                         self._maybe(x.shape[3], self.tp))
        else:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))
