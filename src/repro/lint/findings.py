"""Finding model, in-line suppressions, and the committed baseline
(DESIGN.md 10).

A ``Finding`` is one violation of the determinism contract at one source
location.  Its identity (``key``) is deliberately **line-number free** -
``RULE:path:scope#occurrence`` - so a committed baseline survives
unrelated edits above a grandfathered site; only adding/removing a
violation inside the same scope shifts keys.

Suppressions are per-line: ``# lint: disable=R203(reason)`` on the
statement's first physical line silences exactly that rule there.  The
reason is not optional in spirit - the text output prints it, review
reads it - but the parser tolerates a bare rule id so a suppression
can never be syntactically "wrong enough" to be ignored.

The baseline file (``lint/baseline.json``) holds grandfathered finding
keys.  The gate is zero-*new*-violations: a finding whose key is in the
baseline passes, a baseline key with no matching finding is **stale**
and also fails (the debt was paid; the ledger must say so).  Regenerate
with ``python -m repro.lint --write-baseline``.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = ["Finding", "assign_indices", "suppressions_for",
           "apply_suppressions", "load_baseline", "save_baseline",
           "diff_baseline", "render_text", "render_json"]

# `# lint: disable=R101, R203(reason text)` - comma-separated rule tokens,
# each optionally carrying a parenthesized reason
_DISABLE_RE = re.compile(r"#\s*lint:\s*disable=(.+)$")
_TOKEN_RE = re.compile(r"\s*([A-Za-z][A-Za-z0-9_]*)\s*(?:\(([^)]*)\))?")


@dataclass
class Finding:
    """One determinism-contract violation at one source location."""

    rule: str                  # stable rule id, e.g. "R203"
    path: str                  # repo-relative posix path
    line: int                  # 1-based line of the offending node
    scope: str                 # dotted qualname ("module" at top level)
    message: str
    index: int = 0             # occurrence counter within (rule, path, scope)
    suppressed: Optional[str] = None   # suppression reason when silenced

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.path}:{self.scope}#{self.index}"

    def render(self) -> str:
        tail = f"  [suppressed: {self.suppressed}]" if self.suppressed \
            else ""
        return (f"{self.path}:{self.line}: {self.rule} ({self.scope}) "
                f"{self.message}{tail}")

    def as_dict(self) -> Dict[str, object]:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "scope": self.scope, "message": self.message,
                "key": self.key, "suppressed": self.suppressed}


def assign_indices(findings: Sequence[Finding]) -> List[Finding]:
    """Stamp each finding's occurrence index within its (rule, path,
    scope) bucket, in source order, so keys are stable and unique."""
    ordered = sorted(findings, key=lambda f: (f.path, f.line, f.rule))
    seen: Dict[Tuple[str, str, str], int] = {}
    for f in ordered:
        bucket = (f.rule, f.path, f.scope)
        f.index = seen.get(bucket, 0)
        seen[bucket] = f.index + 1
    return ordered


def suppressions_for(source: str) -> Dict[int, Dict[str, str]]:
    """line (1-based) -> {rule_id: reason} parsed from disable comments."""
    out: Dict[int, Dict[str, str]] = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _DISABLE_RE.search(text)
        if not m:
            continue
        rules: Dict[str, str] = {}
        for tok in m.group(1).split(","):
            tm = _TOKEN_RE.match(tok)
            if tm:
                rules[tm.group(1)] = (tm.group(2) or "").strip() \
                    or "no reason given"
        if rules:
            out[i] = rules
    return out


def apply_suppressions(findings: Iterable[Finding],
                       sources: Dict[str, str]) -> None:
    """Mark findings silenced by a same-line disable comment.  ``all``
    as the rule id silences every rule on that line."""
    cache: Dict[str, Dict[int, Dict[str, str]]] = {}
    for f in findings:
        src = sources.get(f.path)
        if src is None:
            continue
        sup = cache.setdefault(f.path, suppressions_for(src))
        rules = sup.get(f.line, {})
        if f.rule in rules:
            f.suppressed = rules[f.rule]
        elif "all" in rules:
            f.suppressed = rules["all"]


# -- baseline ---------------------------------------------------------------

def load_baseline(path: Path) -> List[str]:
    if not path.exists():
        return []
    data = json.loads(path.read_text())
    keys = data.get("findings", []) if isinstance(data, dict) else data
    if not isinstance(keys, list) \
            or not all(isinstance(k, str) for k in keys):
        raise ValueError(f"malformed baseline {path}: want a JSON list "
                         "of finding keys under 'findings'")
    return keys


def save_baseline(path: Path, findings: Sequence[Finding]) -> None:
    keys = sorted(f.key for f in findings if not f.suppressed)
    path.write_text(json.dumps(
        {"comment": "grandfathered determinism-lint findings; the gate "
                    "fails on NEW findings or on stale entries here - "
                    "regen: python -m repro.lint --write-baseline",
         "findings": keys}, indent=1) + "\n")


def diff_baseline(findings: Sequence[Finding], baseline: Sequence[str]
                  ) -> Tuple[List[Finding], List[str]]:
    """(new findings not grandfathered, stale baseline keys)."""
    active = {f.key: f for f in findings if not f.suppressed}
    base = set(baseline)
    new = [f for k, f in sorted(active.items()) if k not in base]
    stale = sorted(base - set(active))
    return new, stale


# -- rendering --------------------------------------------------------------

def render_text(findings: Sequence[Finding], new: Sequence[Finding],
                stale: Sequence[str]) -> str:
    lines: List[str] = []
    suppressed = [f for f in findings if f.suppressed]
    for f in findings:
        lines.append(f.render())
    lines.append(f"-- {len(findings)} finding(s): "
                 f"{len(new)} new, "
                 f"{len(findings) - len(new) - len(suppressed)} "
                 f"grandfathered, {len(suppressed)} suppressed")
    if stale:
        lines.append(f"-- {len(stale)} STALE baseline entr"
                     f"{'y' if len(stale) == 1 else 'ies'} "
                     "(fixed findings still in lint/baseline.json; "
                     "run --write-baseline):")
        lines.extend(f"   {k}" for k in stale)
    if new:
        lines.append(f"-- {len(new)} NEW finding(s) "
                     "(fix, suppress with a reason, or --write-baseline):")
        lines.extend(f"   {f.key}" for f in new)
    return "\n".join(lines)


def render_json(findings: Sequence[Finding], new: Sequence[Finding],
                stale: Sequence[str]) -> str:
    return json.dumps({
        "findings": [f.as_dict() for f in findings],
        "new": [f.key for f in new],
        "stale_baseline": list(stale),
        "counts": {
            "total": len(findings),
            "new": len(new),
            "suppressed": sum(1 for f in findings if f.suppressed),
            "stale_baseline": len(stale),
        },
        "ok": not new and not stale,
    }, indent=1, sort_keys=True)
