"""Orchestration: collect sources, run every rule family, apply
suppressions, diff against the committed baseline.

Split from the CLI so tests can lint an in-memory source map (fixture
snippets) without touching the filesystem or git.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from . import pickle_lint, rules, surface
from .contract import (BASELINE_PATH, SCAN_ROOTS, TIEBREAK_PREFIXES,
                       WALLCLOCK_ALLOWLIST)
from .findings import (Finding, apply_suppressions, assign_indices,
                       diff_baseline, load_baseline, render_json,
                       render_text, save_baseline)

__all__ = ["LintResult", "collect_sources", "lint_sources", "run_lint",
           "lint_snippet"]


@dataclass
class LintResult:
    findings: List[Finding]
    new: List[Finding]
    stale_baseline: List[str]
    baseline: List[str] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.new and not self.stale_baseline

    def render_text(self) -> str:
        return render_text(self.findings, self.new, self.stale_baseline)

    def render_json(self) -> str:
        return render_json(self.findings, self.new, self.stale_baseline)


def collect_sources(repo_root: Path,
                    roots: Sequence[str] = SCAN_ROOTS
                    ) -> Dict[str, str]:
    """repo-relative posix path -> source, for every scanned .py file."""
    out: Dict[str, str] = {}
    for root in roots:
        base = repo_root / root
        if not base.exists():
            continue
        for f in sorted(base.rglob("*.py")):
            rel = f.relative_to(repo_root).as_posix()
            if rel.startswith("src/repro/lint/"):
                continue                     # the linter lints the
                #                              simulator, not itself
            out[rel] = f.read_text()
    return out


def lint_sources(sources: Dict[str, str], repo_root: Path,
                 *, structural: bool = True) -> List[Finding]:
    """All rule families over a source map.  ``structural=False`` skips
    the roster-driven R3/R5 checks (used for fixture snippets, whose
    paths are not real contract surfaces)."""
    findings: List[Finding] = []
    for path, src in sources.items():
        findings.extend(rules.scan_source(
            src, path,
            tiebreak_scope=path.startswith(TIEBREAK_PREFIXES),
            allow_wallclock=path in WALLCLOCK_ALLOWLIST))
        findings.extend(pickle_lint.check_pickle(src, path))
    if structural:
        findings.extend(surface.check_contract(sources, repo_root))
        findings.extend(surface.check_slots(sources))
    apply_suppressions(findings, sources)
    return assign_indices(findings)


def run_lint(repo_root: Path, *,
             baseline_path: Optional[Path] = None,
             write_baseline: bool = False) -> LintResult:
    """The full gate: scan the tree, diff against the baseline."""
    bpath = baseline_path or (repo_root / BASELINE_PATH)
    sources = collect_sources(repo_root)
    findings = lint_sources(sources, repo_root)
    if write_baseline:
        save_baseline(bpath, findings)
    baseline = load_baseline(bpath)
    new, stale = diff_baseline(findings, baseline)
    return LintResult(findings, new, stale, baseline)


def lint_snippet(source: str, path: str = "src/repro/cluster/snippet.py"
                 ) -> List[Finding]:
    """Lint one in-memory snippet (fixture-test helper).  The default
    path puts the snippet inside the tie-break scope; pass a path
    outside ``cluster/``/``serving/`` to test scope gating."""
    return lint_sources({path: source}, Path("."), structural=False)
