"""Shared neural-net layers (pure JAX, functional, shard-friendly).

Conventions:
* params are plain nested dicts of jnp arrays; every function takes the
  relevant sub-dict explicitly.
* activations flow as (batch, seq, ...); attention works in grouped-query
  layout (B, S, n_kv, group, d_head) so GQA never materializes repeated
  KV heads.
* long sequences use a chunked online-softmax attention (flash-style dataflow
  expressed in XLA: lax.scan over KV chunks carrying running max/sum), so the
  32k-prefill cells compile without materializing S x S score matrices.  The
  Pallas kernel in ``repro.kernels.flash_attention`` is the TPU-optimized
  version of the same dataflow.
* KV caches are ring buffers: full-attention archs size them at max_len,
  sliding-window archs at the window, which is what makes mixtral's
  long_500k decode cell feasible.
"""

from __future__ import annotations

import functools
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Chunk sizes for the chunked-attention scan (tuned for VMEM-sized tiles).
Q_CHUNK = 512
KV_CHUNK = 1024
# Use plain (materialized-scores) attention below this sequence length.
CHUNKED_ATTN_THRESHOLD = 2048

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray,
             eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dtype)


def rms_norm_init(dim: int, dtype) -> jnp.ndarray:
    return jnp.ones((dim,), dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., seq, heads..., d_head); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    # broadcast over head axes between seq and d_head
    extra = x.ndim - angles.ndim - 1
    for _ in range(extra):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_params(key, d_model: int, n_heads: int, n_kv: int,
                     d_head: int, qk_norm: bool, dtype) -> Dict:
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * d_head, dtype),
        "wk": dense_init(ks[1], d_model, n_kv * d_head, dtype),
        "wv": dense_init(ks[2], d_model, n_kv * d_head, dtype),
        "wo": dense_init(ks[3], n_heads * d_head, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = rms_norm_init(d_head, dtype)
        p["k_norm"] = rms_norm_init(d_head, dtype)
    return p


def _plain_attention(q, k, v, q_pos, k_pos, window: int,
                     causal: bool = True) -> jnp.ndarray:
    """Flat-head attention.  q: (B,S,H,D); k,v: (B,T,H,D); int32 positions.

    The flat H layout keeps attention shardable by a single mesh axis
    (GQA KV heads are repeated up to H by the caller - "kv replication")."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32) * scale
    mask = (k_pos >= 0)[None, :]                            # unwritten slots
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _grouped_decode_attention(q, k, v, q_pos, k_pos, window: int):
    """Decode attention without KV repetition (cache stays kv-width).

    q: (B,S,Hkv,G,D); k,v: (B,T,Hkv,D).  The cache's T dim is sharded over
    the mesh (see ShardingRules.cache_specs); XLA turns the softmax
    reductions into the distributed flash-decode combine."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bshgd,bthd->bhgst", q, k).astype(jnp.float32) * scale
    mask = k_pos[None, :] <= q_pos[:, None]
    if window:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    mask &= (k_pos >= 0)[None, :]
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgst,bthd->bshgd", probs, v)


def _chunk_mask(qp_blk, kp_blk, window: int, causal: bool):
    mask = (kp_blk >= 0)[None, :]
    if causal:
        mask = mask & (kp_blk[None, :] <= qp_blk[:, None])
        if window:
            mask = mask & ((qp_blk[:, None] - kp_blk[None, :]) < window)
    return mask


def _flash_fwd_impl(q, k, v, q_pos, k_pos, window: int, causal: bool):
    """Online-softmax forward.  Returns (out (B,S,H,D), lse (B,H,S))."""
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    q_chunks = max(1, S // Q_CHUNK)
    kv_chunks = max(1, T // KV_CHUNK)
    qc, kc = S // q_chunks, T // kv_chunks

    qr = q.reshape(B, q_chunks, qc, H, D).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(q_chunks, qc)
    kr = k.reshape(B, kv_chunks, kc, H, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, kv_chunks, kc, H, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(kv_chunks, kc)

    def per_q_chunk(args):
        q_blk, qp_blk = args
        m0 = jnp.full((B, H, qc), NEG_INF, jnp.float32)
        s0 = jnp.zeros((B, H, qc), jnp.float32)
        a0 = jnp.zeros((B, qc, H, D), jnp.float32)

        def step(carry, kv_blk):
            m, s, acc = carry
            k_blk, v_blk, kp_blk = kv_blk
            sc = jnp.einsum("bshd,bthd->bhst", q_blk, k_blk
                            ).astype(jnp.float32) * scale
            mask = _chunk_mask(qp_blk, kp_blk, window, causal)
            sc = jnp.where(mask[None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            m_safe = jnp.maximum(m_new, -1e29)   # fully-masked row guard
            p = jnp.exp(sc - m_safe[..., None])
            corr = jnp.exp(jnp.maximum(m, -1e29) - m_safe)
            s_new = s * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhst,bthd->bshd", p.astype(q.dtype), v_blk
                            ).astype(jnp.float32)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
            return (m_new, s_new, acc_new), None

        (m, s, acc), _ = jax.lax.scan(step, (m0, s0, a0), (kr, vr, kp))
        denom = jnp.maximum(s, 1e-30)
        out = (acc / denom.transpose(0, 2, 1)[..., None]).astype(q.dtype)
        lse = jnp.maximum(m, -1e29) + jnp.log(denom)      # (B,H,qc)
        return out, lse

    out, lse = jax.lax.map(per_q_chunk, (qr, qp))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    lse = lse.transpose(1, 2, 0, 3).reshape(B, H, S)
    return out, lse


def _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, dout,
                    window: int, causal: bool):
    """Flash backward: recompute probabilities chunk-by-chunk; the full
    (S, T) score matrix is never resident (the scan-VJP of the naive
    chunked form would save it - 4 GiB/device/layer at 4k)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    q_chunks = max(1, S // Q_CHUNK)
    kv_chunks = max(1, T // KV_CHUNK)
    qc, kc = S // q_chunks, T // kv_chunks
    f32 = jnp.float32

    # delta_i = rowsum(dO_i * O_i)   (B,H,S)
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(f32), out.astype(f32))

    qr = q.reshape(B, q_chunks, qc, H, D).transpose(1, 0, 2, 3, 4)
    qp = q_pos.reshape(q_chunks, qc)
    kr = k.reshape(B, kv_chunks, kc, H, D).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(B, kv_chunks, kc, H, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(kv_chunks, kc)
    dor = dout.reshape(B, q_chunks, qc, H, D).transpose(1, 0, 2, 3, 4)
    lser = lse.reshape(B, H, q_chunks, qc).transpose(2, 0, 1, 3)
    dlr = delta.reshape(B, H, q_chunks, qc).transpose(2, 0, 1, 3)

    def p_block(q_blk, k_blk, qp_blk, kp_blk, lse_blk):
        sc = jnp.einsum("bshd,bthd->bhst", q_blk, k_blk
                        ).astype(f32) * scale
        mask = _chunk_mask(qp_blk, kp_blk, window, causal)
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        return jnp.exp(sc - lse_blk[..., None])          # (B,H,qc,kc)

    # --- dq: map over q chunks, scan kv chunks -------------------------------
    def dq_chunk(args):
        q_blk, qp_blk, do_blk, lse_blk, dl_blk = args

        def step(dq_acc, kv_blk):
            k_blk, v_blk, kp_blk = kv_blk
            p = p_block(q_blk, k_blk, qp_blk, kp_blk, lse_blk)
            dp = jnp.einsum("bshd,bthd->bhst", do_blk, v_blk).astype(f32)
            ds = p * (dp - dl_blk[..., None])
            dq_acc = dq_acc + jnp.einsum(
                "bhst,bthd->bshd", ds.astype(q.dtype), k_blk
            ).astype(f32) * scale
            return dq_acc, None

        dq0 = jnp.zeros((B, qc, H, D), f32)
        dq_blk, _ = jax.lax.scan(step, dq0, (kr, vr, kp))
        return dq_blk

    dq = jax.lax.map(dq_chunk, (qr, qp, dor, lser, dlr))
    dq = dq.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D).astype(q.dtype)

    # --- dk/dv: map over kv chunks, scan q chunks ------------------------------
    def dkv_chunk(args):
        k_blk, v_blk, kp_blk = args

        def step(carry, q_blk_all):
            dk_acc, dv_acc = carry
            q_blk, qp_blk, do_blk, lse_blk, dl_blk = q_blk_all
            p = p_block(q_blk, k_blk, qp_blk, kp_blk, lse_blk)
            dv_acc = dv_acc + jnp.einsum(
                "bhst,bshd->bthd", p.astype(q.dtype), do_blk).astype(f32)
            dp = jnp.einsum("bshd,bthd->bhst", do_blk, v_blk).astype(f32)
            ds = p * (dp - dl_blk[..., None])
            dk_acc = dk_acc + jnp.einsum(
                "bhst,bshd->bthd", ds.astype(q.dtype), q_blk
            ).astype(f32) * scale
            return (dk_acc, dv_acc), None

        z = jnp.zeros((B, kc, H, D), f32)
        (dk_blk, dv_blk), _ = jax.lax.scan(
            step, (z, z), (qr, qp, dor, lser, dlr))
        return dk_blk, dv_blk

    dk, dv = jax.lax.map(dkv_chunk, (kr, vr, kp))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D).astype(k.dtype)
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(B, T, H, D).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _fa(q, k, v, q_pos, k_pos, window: int, causal: bool):
    out, _ = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, causal)
    return out


def _fa_fwd(q, k, v, q_pos, k_pos, window, causal):
    out, lse = _flash_fwd_impl(q, k, v, q_pos, k_pos, window, causal)
    return out, (q, k, v, q_pos, k_pos, out, lse)


def _fa_bwd(window, causal, res, dout):
    q, k, v, q_pos, k_pos, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q, k, v, q_pos, k_pos, out, lse, dout,
                                 window, causal)
    zero_pos = np.zeros(q_pos.shape, jax.dtypes.float0)
    zero_kpos = np.zeros(k_pos.shape, jax.dtypes.float0)
    return dq, dk, dv, zero_pos, zero_kpos


_fa.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, q_pos, k_pos, window: int = 0,
                    causal: bool = True) -> jnp.ndarray:
    """Memory-efficient attention with a flash-style custom VJP.

    Flat-head layout (B,S,H,D) / (B,T,H,D).  Forward saves only
    (q,k,v,out,lse); backward recomputes score chunks, so the full (S,T)
    score matrix is never resident in either pass.  This is the XLA
    reference implementation of ``repro.kernels.flash_attention``."""
    return _fa(q, k, v, q_pos, k_pos, int(window), bool(causal))


def _cache_write(cache: Dict, k: jnp.ndarray, v: jnp.ndarray,
                 cache_pos) -> Dict:
    """Write the last min(S, Tc) tokens of k/v into the ring buffer."""
    Tc = cache["k"].shape[1]
    S = k.shape[1]
    Lw = min(S, Tc)
    slots = (cache_pos + S - Lw + jnp.arange(Lw)) % Tc
    ck = cache["k"].at[:, slots].set(k[:, -Lw:].astype(cache["k"].dtype))
    cv = cache["v"].at[:, slots].set(v[:, -Lw:].astype(cache["v"].dtype))
    return {"k": ck, "v": cv}


def _cache_slot_positions(Tc: int, cache_pos, S: int) -> jnp.ndarray:
    """Absolute position held by ring slot i after writing S tokens:
    p(i) = last - ((last - i) mod Tc), last = cache_pos + S - 1; -1 if
    the slot has never been written."""
    last = cache_pos + S - 1
    idx = jnp.arange(Tc)
    k_pos = last - ((last - idx) % Tc)
    return jnp.where(k_pos <= last, k_pos, -1)


def multihead_attention(
    p: Dict,
    x: jnp.ndarray,                 # (B, S, d_model)
    positions: jnp.ndarray,         # (S,) absolute positions of x
    kv_src: Optional[jnp.ndarray],  # cross-attn source or None (self)
    cache: Optional[Dict],          # {"k","v"} ring buffers or None
    cache_pos,                      # scalar: tokens already in cache
    *,
    n_heads: int,
    n_kv: int,
    d_head: int,
    qk_norm: bool = False,
    rope_theta: float = 1e4,
    window: int = 0,
    causal: bool = True,
    decode: bool = False,           # True: attend over the cache (S small)
    is_cross: bool = False,         # cross-attention (kv from encoder/cache)
    eps: float = 1e-5,
    sc=lambda x, kind=None: x,      # sharding-constraint hook
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Returns (output (B,S,d_model), updated cache).

    Modes:
      train   - cache is None: attend within the sequence.
      prefill - cache given, decode=False: attend within the sequence,
                write the last min(S, cache_len) tokens into the ring.
      decode  - cache given, decode=True: write current token(s), attend
                over the whole ring buffer.
    """
    B, S, _ = x.shape
    G = n_heads // n_kv
    if decode:
        # grouped layout (no KV repetition): the cache stays kv-width and is
        # sharded along its sequence dim (distributed flash-decode).
        q = (x @ p["wq"]).reshape(B, S, n_kv, G, d_head)
    else:
        # flat-head layout: shardable by a single mesh axis over H
        q = (x @ p["wq"]).reshape(B, S, n_heads, d_head)

    if is_cross and decode:
        # cross-attention decode: k/v live in the (static) cross cache
        k, v = cache["k"], cache["v"]
        new_cache = cache
        k_pos = jnp.arange(k.shape[1])
        q_pos = positions
        k_full, v_full = k, v
    else:
        src = x if kv_src is None else kv_src
        Tsrc = src.shape[1]
        k = (src @ p["wk"]).reshape(B, Tsrc, n_kv, d_head)
        v = (src @ p["wv"]).reshape(B, Tsrc, n_kv, d_head)

        if qk_norm:
            q = rms_norm(q, p["q_norm"], eps)
            k = rms_norm(k, p["k_norm"], eps)

        use_rope = not is_cross  # RoPE only for self-attention
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
            src_pos = positions if not decode else (
                cache_pos + jnp.arange(Tsrc))
            k = apply_rope(k, src_pos, rope_theta)

        if cache is not None:
            new_cache = _cache_write(cache, k, v, cache_pos)
            if decode:
                Tc = cache["k"].shape[1]
                k_full, v_full = new_cache["k"], new_cache["v"]
                k_pos = _cache_slot_positions(Tc, cache_pos, S)
                q_pos = cache_pos + jnp.arange(S)
            else:
                # prefill: attend within the sequence
                k_full, v_full = k, v
                k_pos = positions[:Tsrc]
                q_pos = positions
        else:
            new_cache = None
            k_full, v_full = k, v
            k_pos = positions[:Tsrc] if use_rope else jnp.arange(Tsrc)
            q_pos = positions

    if decode:
        if not causal:
            scale = 1.0 / math.sqrt(d_head)
            scores = jnp.einsum("bshgd,bthd->bhgst", q, k_full
                                ).astype(jnp.float32) * scale
            probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
            out = jnp.einsum("bhgst,bthd->bshgd", probs, v_full)
        else:
            out = _grouped_decode_attention(q, k_full, v_full, q_pos, k_pos,
                                            window)
        out = out.reshape(B, S, n_heads * d_head) @ p["wo"]
        return out, new_cache

    if G > 1:
        # kv replication: repeat KV heads up to H so the flat head dim
        # shards on one mesh axis (caches store the original kv width).
        # k/v stay replicated over the model axis when kv doesn't divide
        # it; the q-head-sharded einsums then read them locally (no
        # involuntary resharding).
        k_full = jnp.repeat(k_full, G, axis=2)
        v_full = jnp.repeat(v_full, G, axis=2)
    else:
        # full MHA: k/v arrive head-sharded from the projections; pin that
        # sharding so it survives into the nested flash scan bodies
        # (without the pin, propagation degrades and XLA all-gathers K/V
        # chunks inside the loops - H-D2, EXPERIMENTS.md section Perf).
        k_full = sc(k_full, "heads")
        v_full = sc(v_full, "heads")
    q = sc(q, "heads")

    T = k_full.shape[1]
    if S % Q_CHUNK == 0 and T % KV_CHUNK == 0:
        out = flash_attention(q, k_full, v_full, q_pos, k_pos, window,
                              causal)
    else:
        out = _plain_attention(q, k_full, v_full, q_pos, k_pos, window,
                               causal)
    out = sc(out, "heads")
    out = out.reshape(B, S, n_heads * d_head) @ p["wo"]
    return out, new_cache


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------


def mlp_params(key, d_model: int, d_ff: int, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(ks[0], d_model, d_ff, dtype),
        "wi_up": dense_init(ks[1], d_model, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, d_model, dtype),
    }


def mlp(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    return (jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])) @ p["wo"]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Numerically-stable CE; logits (B,S,V) any float dtype, targets int."""
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_softmax_xent(x: jnp.ndarray, w: jnp.ndarray,
                         targets: jnp.ndarray,
                         mask: Optional[jnp.ndarray],
                         sc, chunk: int = 512) -> jnp.ndarray:
    """CE over the LM head without materializing full (B,S,V) logits.

    Scans sequence chunks, rematerializing each chunk's logits in the
    backward pass (jax.checkpoint).  Transient memory drops from
    O(B*S*V) to O(B*chunk*V) - required for 4k x 152k-vocab train cells.
    """
    B, S, D = x.shape
    if S <= chunk:
        logits = sc(x @ w, "logits")
        return cross_entropy(logits, targets, mask)
    n = S // chunk
    xc = x.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = (mask.reshape(B, n, chunk).transpose(1, 0, 2) if mask is not None
          else jnp.ones((n, B, chunk), jnp.float32))

    @jax.checkpoint
    def body(carry, inp):
        xb, tb, mb = inp
        logits = sc(xb @ w, "logits").astype(jnp.float32)
        m = jnp.max(logits, axis=-1, keepdims=True)
        lse = m[..., 0] + jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1))
        gold = jnp.take_along_axis(logits, tb[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mb
        return (carry[0] + nll.sum(), carry[1] + mb.sum()), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (xc, tc, mc))
    return tot / jnp.maximum(cnt, 1.0)
