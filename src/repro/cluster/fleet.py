"""Shared-clock virtual-time fleet of serving replicas (DESIGN.md L2).

One event loop, N ``SimServeEngine`` replicas.  Three event kinds on a
single heap keyed by virtual milliseconds (ties broken by insertion order,
so runs are exactly deterministic under a fixed seed):

* ``arrive``  - the open-loop workload injects a request; the router picks
  a replica; if that replica is idle it starts a decode step;
* ``step``    - a replica's in-flight decode step completes; streams that
  were routed to it mid-step join the next step (continuous batching);
* ``scale``   - periodic autoscaler hook: queue-depth-triggered scale-out
  adds a replica to the live pool (routers see it on the next arrival).

Decode-step effects are applied when the step *starts* (token counts and
completion times are stamped with the step's end time, so all observables
are consistent); the heap only sequences step boundaries.  This is the
same arrivals-join-at-step-boundaries semantics as the single-replica
``SimServeEngine.run`` loop.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from ..serving.engine import (Request, SimServeEngine, StepCostModel,
                              make_admission)
from .router import Router
from .telemetry import ClusterResult, ClusterTelemetry, SLO
from .workload import WorkloadSpec


def knee_cost(spec: WorkloadSpec, active_limit: int,
              oversub: float = 2.0) -> StepCostModel:
    """Cost model whose HBM knee sits at ``oversub`` x the footprint of a
    full active set under ``spec``'s mean request shape.

    Used by the benches/tests so collapse physics stays reachable at
    scaled-down workload sizes; derives from ``kv_bytes_per_tok`` so the
    knee tracks the cost model instead of a copy-pasted constant."""
    base = StepCostModel()
    mean_resident = spec.mean_prompt + spec.mean_gen / 2
    return dataclasses.replace(
        base,
        hbm_budget=oversub * active_limit * mean_resident
        * base.kv_bytes_per_tok)


def est_capacity_rps(spec: WorkloadSpec, active_limit: int,
                     n_replicas: int,
                     cost: Optional[StepCostModel] = None) -> float:
    """Analytic saturation point: full active set, no thrash, no pod mix."""
    cost = cost or StepCostModel()
    mean_resident = spec.mean_prompt + spec.mean_gen / 2
    step_ms = cost.step_ms(active_limit, int(active_limit * mean_resident),
                           0.0)
    tok_s = active_limit / (step_ms / 1e3)
    return n_replicas * tok_s / spec.mean_gen


@dataclass
class FleetConfig:
    """Replica-pool shape; every replica is identical (heterogeneous pools
    are a roadmap follow-on)."""

    n_replicas: int = 4
    admission: str = "gcr"           # none | gcr | gcr_pod
    active_limit: int = 128
    n_pods: int = 2
    promote_every: int = 64
    cost: Optional[StepCostModel] = None

    def make_engine(self) -> SimServeEngine:
        adm = make_admission(self.admission, self.active_limit,
                             n_pods=self.n_pods,
                             promote_every=self.promote_every)
        return SimServeEngine(adm, cost=self.cost)

    def make_engines(self) -> List[SimServeEngine]:
        return [self.make_engine() for _ in range(self.n_replicas)]


class QueueDepthAutoscaler:
    """Scale out when mean parked depth per replica crosses a threshold.

    Deliberately the simplest useful policy - a hook point, not the real
    thing (see ROADMAP open items).  Scale-in is absent: parked streams
    cost nothing, so shedding replicas mid-run only loses KV state.
    """

    def __init__(self, cfg: FleetConfig, max_replicas: int = 8,
                 parked_per_replica: Optional[float] = None,
                 cooldown_ms: float = 2000.0) -> None:
        self.cfg = cfg
        self.max_replicas = max_replicas
        # default trigger: a full active set's worth of parked streams
        self.parked_per_replica = (float(cfg.active_limit)
                                   if parked_per_replica is None
                                   else parked_per_replica)
        self.cooldown_ms = cooldown_ms
        self._last_scale_ms = -1e18

    def __call__(self, fleet: "Fleet", now_ms: float
                 ) -> Optional[SimServeEngine]:
        if len(fleet.replicas) >= self.max_replicas:
            return None
        if now_ms - self._last_scale_ms < self.cooldown_ms:
            return None
        parked = sum(r.admission.num_parked for r in fleet.replicas)
        if parked / len(fleet.replicas) <= self.parked_per_replica:
            return None
        self._last_scale_ms = now_ms
        return self.cfg.make_engine()


class Fleet:
    """N replicas + router + telemetry on one virtual clock."""

    def __init__(self, replicas: List[SimServeEngine], router: Router,
                 telemetry: Optional[ClusterTelemetry] = None,
                 autoscaler: Optional[
                     Callable[["Fleet", float], Optional[SimServeEngine]]
                 ] = None,
                 autoscale_every_ms: float = 500.0) -> None:
        if not replicas:
            raise ValueError("fleet needs at least one replica")
        self.replicas = replicas
        self.router = router
        self.telemetry = telemetry or ClusterTelemetry()
        self.autoscaler = autoscaler
        self.autoscale_every_ms = autoscale_every_ms

    # -- event loop ----------------------------------------------------------
    def run(self, requests: List[Request], max_ms: float = 120_000.0
            ) -> ClusterResult:
        heap: list = []
        seq = itertools.count()
        stepping = [False] * len(self.replicas)
        step_end = [0.0] * len(self.replicas)

        # clone on entry: engines mutate Request state in place, and one
        # workload list is typically swept across many policy runs
        for r in sorted(requests, key=lambda r: (r.arrive_ms, r.rid)):
            heapq.heappush(heap, (r.arrive_ms, next(seq), "arrive",
                                  r.fresh()))
        if self.autoscaler is not None:
            heapq.heappush(heap,
                           (self.autoscale_every_ms, next(seq), "scale", None))

        def start_step(i: int, t: float) -> None:
            dt, _done = self.replicas[i].step(t)
            if dt > 0.0:
                stepping[i] = True
                step_end[i] = t + dt
                heapq.heappush(heap, (t + dt, next(seq), "step", i))

        now = 0.0
        injected = 0
        while heap:
            t, _, kind, payload = heapq.heappop(heap)
            if t > max_ms:
                break
            if kind != "scale":
                # bookkeeping ticks must not extend the measured duration
                now = t
            if kind == "arrive":
                req: Request = payload
                injected += 1
                i = self.router.route(req, self.replicas)
                self.replicas[i].submit(req)
                self.telemetry.sample(i, self.replicas[i])
                if not stepping[i] and self.replicas[i].has_work:
                    start_step(i, t)
            elif kind == "step":
                i = payload
                stepping[i] = False
                self.telemetry.sample(i, self.replicas[i])
                if self.replicas[i].has_work:
                    start_step(i, t)
            elif kind == "scale":
                new = self.autoscaler(self, t) if self.autoscaler else None
                if new is not None:
                    self.replicas.append(new)
                    stepping.append(False)
                    step_end.append(0.0)
                    self.telemetry.on_scale(t)
                # keep ticking while any work remains on the heap
                if any(k in ("arrive", "step") for _, _, k, _ in heap):
                    heapq.heappush(
                        heap,
                        (t + self.autoscale_every_ms, next(seq), "scale",
                         None))
        # offered = requests that actually arrived before the max_ms cutoff,
        # so completed + live == offered holds for any (workload, max_ms).
        # Step effects are banked at step start, so a truncated run must
        # extend the measured end over in-flight steps - their tokens and
        # completion stamps are already counted (the single-engine loop has
        # the same now += dt overshoot past max_ms).
        end = max([now] + [e for i, e in enumerate(step_end) if stepping[i]])
        return self.telemetry.finalize(end, self.replicas, injected)


def run_fleet(requests: List[Request], router: Router,
              cfg: Optional[FleetConfig] = None,
              slo: Optional[SLO] = None,
              autoscale: bool = False,
              max_ms: float = 120_000.0) -> ClusterResult:
    """One-call convenience wrapper used by benches, tests, and the CLI."""
    cfg = cfg or FleetConfig()
    telem = ClusterTelemetry(slo or SLO())
    scaler = QueueDepthAutoscaler(cfg) if autoscale else None
    fleet = Fleet(cfg.make_engines(), router, telem, autoscaler=scaler)
    return fleet.run(requests, max_ms=max_ms)
