"""Atomic primitives used by the GCR algorithm (paper Figures 3-5).

The paper's pseudocode relies on three hardware atomics:

* ``FAA``  - fetch-and-add   (Figure 3 line 5/20, Figure 4 line 31)
* ``SWAP`` - atomic exchange (Figure 5 line 39, the MCS-style tail push)
* ``CAS``  - compare-and-swap (Figure 5 lines 52-53, the tail/top pop dance)

CPython does not expose hardware atomics, so each atomic cell carries a tiny
private mutex.  This preserves the *semantics* (linearizable FAA/SWAP/CAS,
starvation-free assuming a fair scheduler - the premise of Theorem 7) at the
cost of some overhead; the discrete-event simulator in ``simulator.py`` is the
vehicle for faithful *performance* claims, while these real-thread primitives
back the framework's actual host-side concurrency.

All cells also expose a relaxed ``load``/``store`` - plain attribute access is
atomic under the GIL, matching the paper's use of plain loads for monitoring
(``numActive`` reads in Figure 3 line 3/17).
"""

from __future__ import annotations

import threading
from typing import Any, Optional


class AtomicInt:
    """Linearizable integer cell with FAA / CAS / SWAP."""

    __slots__ = ("_value", "_mu")

    def __init__(self, value: int = 0) -> None:
        self._value = value
        self._mu = threading.Lock()

    # -- relaxed ops (plain, GIL-atomic) ------------------------------------
    def load(self) -> int:
        return self._value

    def store(self, value: int) -> None:
        # A racy store is acceptable wherever the paper uses a plain store
        # (e.g. resetting topApproved, Figure 3 line 19).
        with self._mu:
            self._value = value

    # -- atomic read-modify-write ops ---------------------------------------
    def faa(self, delta: int) -> int:
        """Fetch-and-add; returns the *previous* value (x86 XADD semantics)."""
        with self._mu:
            prev = self._value
            self._value = prev + delta
            return prev

    def cas(self, expected: int, new: int) -> bool:
        with self._mu:
            if self._value == expected:
                self._value = new
                return True
            return False

    def swap(self, new: int) -> int:
        with self._mu:
            prev = self._value
            self._value = new
            return prev

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"AtomicInt({self._value})"


class AtomicRef:
    """Linearizable reference cell (used for the queue ``top``/``tail``)."""

    __slots__ = ("_value", "_mu")

    def __init__(self, value: Optional[Any] = None) -> None:
        self._value = value
        self._mu = threading.Lock()

    def load(self) -> Optional[Any]:
        return self._value

    def store(self, value: Optional[Any]) -> None:
        with self._mu:
            self._value = value

    def cas(self, expected: Optional[Any], new: Optional[Any]) -> bool:
        """Identity-compare-and-swap (pointer equality, like the hardware op)."""
        with self._mu:
            if self._value is expected:
                self._value = new
                return True
            return False

    def swap(self, new: Optional[Any]) -> Optional[Any]:
        with self._mu:
            prev = self._value
            self._value = new
            return prev

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"AtomicRef({self._value!r})"
