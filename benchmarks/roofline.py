"""Roofline table builder: reads the dry-run artifacts and emits the
EXPERIMENTS.md section-Roofline table plus CSV rows for benchmarks.run."""

from __future__ import annotations

import json
from pathlib import Path
from typing import List, Tuple

ROOT = Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"

Row = Tuple[str, float, str]


def load_cells(mesh: str = "16x16") -> List[dict]:
    d = DRYRUN_DIR / mesh
    cells = []
    for f in sorted(d.glob("*.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def roofline_rows(mesh: str = "16x16") -> List[Row]:
    rows: List[Row] = []
    for c in load_cells(mesh):
        key = f"roofline/{c['arch']}/{c['shape']}"
        t = c["roofline"]
        rows.append((f"{key}/compute_s", t["compute_s"], ""))
        rows.append((f"{key}/memory_s", t["memory_s"], ""))
        rows.append((f"{key}/collective_s", t["collective_s"],
                     f"dom={c['dominant'].replace('_s','')}"))
    return rows


def markdown_table(mesh: str = "16x16") -> str:
    lines = [
        f"| arch | shape | compute s | memory s | collective s | dominant |"
        f" peak GiB/dev | useful FLOPs ratio |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in load_cells(mesh):
        t = c["roofline"]
        useful = c.get("useful_flops_ratio")
        lines.append(
            f"| {c['arch']} | {c['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{c['dominant'].replace('_s', '')} | "
            f"{c['memory']['temp_bytes'] / 2**30:.2f} | "
            f"{useful:.2f} |" if useful else
            f"| {c['arch']} | {c['shape']} | - | - | - | - | - | - |")
    return "\n".join(lines)


def summary() -> List[Row]:
    rows = []
    for mesh in ["16x16", "2x16x16"]:
        cells = load_cells(mesh)
        if not cells:
            continue
        rows.append((f"dryrun/{mesh}/cells_compiled", len(cells), ""))
        doms = {}
        for c in cells:
            doms[c["dominant"]] = doms.get(c["dominant"], 0) + 1
        for d, n in sorted(doms.items()):
            rows.append((f"dryrun/{mesh}/dominant_{d}", n, ""))
    return rows
