"""Int8 gradient compression with error feedback (cross-pod hop).

Distributed-optimization trick for the slow inter-pod link: gradients are
quantized to int8 with a per-tensor scale before the cross-pod reduction
and the quantization error is fed back into the next step (error-feedback
keeps SGD/Adam convergence unbiased in expectation).

Intended placement (see parallel/collectives.py): reduce-scatter full
precision *inside* a pod (fast ICI), quantize only the pod-level partial
sums for the DCN all-reduce across pods, dequantize, all-gather.  8x less
cross-pod traffic for the dominant term of hierarchical grad sync.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_with_feedback(grads, error_state):
    """Returns ((q, scale) tree, new_error_state).

    new_error = (g + error) - dequant(quant(g + error))
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return (q, scale), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qtree = jax.tree.unflatten(treedef, [p[0] for p in pairs])
    etree = jax.tree.unflatten(treedef, [p[1] for p in pairs])
    return qtree, etree


def decompress(qtree):
    def is_leaf(x):
        return isinstance(x, tuple) and len(x) == 2
    return jax.tree.map(lambda qs: dequantize_int8(*qs), qtree,
                        is_leaf=is_leaf)
