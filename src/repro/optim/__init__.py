"""Optimizers and distributed-optimization tricks."""

from .adamw import adamw_init, adamw_update
from .schedules import cosine_schedule

__all__ = ["adamw_init", "adamw_update", "cosine_schedule"]
