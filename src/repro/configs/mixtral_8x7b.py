"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention
[arXiv:2401.04088].  32L d_model=4096 32H(kv=8) expert d_ff=14336
vocab=32000, window=4096."""

import dataclasses

from ..config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=("moe",),
    sliding_window=4096,
    n_experts=8,
    n_experts_active=2,
    moe_d_ff=14336,
    gcr_moe=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, sliding_window=32, n_experts=4, n_experts_active=2,
    moe_d_ff=128)
